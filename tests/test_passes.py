"""Compiler-pass tests: fusion numerics, DCE, constant folding, shape
inference vs. executed shapes, multi-output binding, mixed-precision
exploration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.ir import Graph, Node, TensorInfo
from repro.core.passes import (PassManager, default_pipeline,
                               eliminate_dead_nodes, fold_constants,
                               fuse_conv_bn_relu, fuse_gemm_relu,
                               infer_shapes, make_assign_precision)
from repro.core.reader import cnn_to_ir, mlp_to_ir
from repro.core.writers.jax_writer import JaxWriter
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig, PrecisionMap


@pytest.fixture(scope="module")
def cnn_graph():
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()}, batch=3)
    x = jax.random.uniform(jax.random.PRNGKey(1), (3, 28, 28, 1))
    return g, x


@pytest.fixture(scope="module")
def mlp_graph():
    sizes = [12, 8, 5]
    rng = np.random.default_rng(0)
    params = {}
    for i in range(2):
        params[f"fc{i}/w"] = rng.normal(size=(sizes[i], sizes[i + 1])
                                        ).astype(np.float32)
        params[f"fc{i}/b"] = rng.normal(size=(sizes[i + 1],)).astype(np.float32)
    g = mlp_to_ir(sizes, params, batch=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12))
    return g, x


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------

def test_fusion_matches_unfused_reference(cnn_graph):
    g, x = cnn_graph
    ref = JaxWriter(g).build()(x)
    fused = fuse_conv_bn_relu(g)
    ops = [n.op for n in fused.topo_order()]
    assert ops == ["FusedConv", "MaxPool"] * 2 + ["Flatten", "Gemm"]
    out = JaxWriter(fused).build()(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_fusion_direct_conv_bn_relu_chain():
    """Conv -> BN -> Relu with no interposed pool fuses to a single node."""
    rng = np.random.default_rng(1)
    c = 4
    inits = {
        "w": rng.normal(size=(3, 3, 1, c)).astype(np.float32),
        "b": rng.normal(size=(c,)).astype(np.float32),
        "scale": rng.uniform(0.5, 1.5, c).astype(np.float32),
        "bias": rng.normal(size=(c,)).astype(np.float32),
        "mean": rng.normal(size=(c,)).astype(np.float32),
        "var": rng.uniform(0.5, 2.0, c).astype(np.float32),
    }
    g = Graph("t", [
        Node("Conv", "c", ["input", "w", "b"], ["y"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1]}),
        Node("BatchNormalization", "bn", ["y", "scale", "bias", "mean", "var"],
             ["z"], {"epsilon": 1e-5}),
        Node("Relu", "r", ["z"], ["out"]),
    ], [TensorInfo("input", (2, 8, 8, 1))], ["out"], inits)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 8, 1))
    ref = JaxWriter(g).build()(x)
    fused = eliminate_dead_nodes(fuse_conv_bn_relu(g))
    assert [n.op for n in fused.topo_order()] == ["FusedConv"]
    assert fused.nodes[0].attrs["relu"] is True
    assert set(fused.initializers) == {"w", "b"}  # BN stats swept by DCE
    np.testing.assert_allclose(np.asarray(JaxWriter(fused).build()(x)),
                               np.asarray(ref), atol=1e-5)


def test_gemm_relu_fusion_matches_unfused(mlp_graph):
    """Gemm -> Relu folds into FusedGemm with identical numerics; the final
    Gemm (graph output, no Relu) stays untouched."""
    g, x = mlp_graph
    ref = JaxWriter(g).build()(x)
    fused = fuse_gemm_relu(g)
    ops = [n.op for n in fused.topo_order()]
    assert ops == ["FusedGemm", "Gemm"]
    fg = fused.topo_order()[0]
    assert fg.attrs["relu"] is True and fg.attrs["fused_from"] == ["relu0"]
    out = JaxWriter(fused).build()(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gemm_relu_fusion_in_default_pipeline(mlp_graph):
    g, x = mlp_graph
    res = DesignFlow(g).run(targets=("jax", "stream"))
    ops = [n.op for n in res.graph.topo_order()]
    assert "FusedGemm" in ops and "Relu" not in ops
    raw = DesignFlow(g).run(targets=("jax",), passes=())
    np.testing.assert_allclose(np.asarray(res.executables["jax"](x)),
                               np.asarray(raw.executables["jax"](x)),
                               atol=1e-6)
    # the stream topology sizes FusedGemm FIFOs with the matrix model
    # (whole per-item vector resident) just like Gemm
    topo = res.writers["stream"].topology()
    fg_conns = [c for c in topo["connections"]
                if c["dst"] == "fc0" and c["src"] == "input"]
    assert fg_conns and fg_conns[0]["depth"] == 12


def test_gemm_relu_fusion_skips_fanout_and_outputs():
    """A Gemm whose output feeds two consumers (or the graph output) must not
    fuse — the intermediate FIFO is observable."""
    rng = np.random.default_rng(2)
    inits = {"w/a": rng.normal(size=(4, 4)).astype(np.float32)}
    nodes = [
        Node("Gemm", "g0", ["x", "w/a"], ["h"]),
        Node("Relu", "r0", ["h"], ["r"]),
        Node("Add", "a0", ["h", "r"], ["y"]),     # second consumer of h
    ]
    g = Graph("fanout", nodes, [TensorInfo("x", (2, 4))], ["y"], inits)
    fused = fuse_gemm_relu(g)
    assert [n.op for n in fused.topo_order()] == ["Gemm", "Relu", "Add"]


def test_fusion_negative_bn_scale_across_pool_falls_back():
    """A negative BN scale does not commute with MaxPool — no fusion."""
    c = 2
    inits = {
        "w": np.ones((3, 3, 1, c), np.float32),
        "b": np.zeros((c,), np.float32),
        "scale": np.array([1.0, -1.0], np.float32),
        "bias": np.zeros((c,), np.float32),
        "mean": np.zeros((c,), np.float32),
        "var": np.ones((c,), np.float32),
    }
    g = Graph("t", [
        Node("Conv", "c", ["input", "w", "b"], ["y"],
             {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1]}),
        Node("MaxPool", "p", ["y"], ["yp"],
             {"kernel_shape": [2, 2], "strides": [2, 2]}),
        Node("BatchNormalization", "bn", ["yp", "scale", "bias", "mean", "var"],
             ["out"], {"epsilon": 1e-5}),
    ], [TensorInfo("input", (1, 8, 8, 1))], ["out"], inits)
    fused = fuse_conv_bn_relu(g)
    assert [n.op for n in fused.topo_order()] == \
        ["Conv", "MaxPool", "BatchNormalization"]


def test_fusion_skips_tied_weights():
    """A weight initializer shared by two convs must not be rescaled."""
    c = 2
    inits = {
        "w": np.ones((3, 3, 1, c), np.float32),
        "b": np.zeros((c,), np.float32),
        "scale": np.ones((c,), np.float32),
        "bias": np.zeros((c,), np.float32),
        "mean": np.zeros((c,), np.float32),
        "var": np.full((c,), 3.0, np.float32),
    }
    conv_attrs = {"kernel_shape": [3, 3], "pads": "SAME", "strides": [1, 1]}
    g = Graph("t", [
        Node("Conv", "c1", ["input", "w", "b"], ["y1"], dict(conv_attrs)),
        Node("BatchNormalization", "bn", ["y1", "scale", "bias", "mean", "var"],
             ["z"], {"epsilon": 1e-5}),
        Node("Conv", "c2", ["input2", "w", "b"], ["y2"], dict(conv_attrs)),
        Node("Add", "sum", ["z", "y2"], ["out"]),
    ], [TensorInfo("input", (1, 8, 8, 1)), TensorInfo("input2", (1, 8, 8, 1))],
        ["out"], inits)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 8, 1))
    ref = JaxWriter(g).build()(x, x)
    fused = fuse_conv_bn_relu(g)
    assert all(n.op != "FusedConv" for n in fused.nodes)
    np.testing.assert_allclose(np.asarray(JaxWriter(fused).build()(x, x)),
                               np.asarray(ref))


def test_calibration_ranges_are_float_ranges(cnn_graph):
    """run() must calibrate the float view of the compiled graph, not the
    already-quantized network (whose ranges are clipped to the 8.0 default)."""
    g, x = cnn_graph
    flow = DesignFlow(g)
    big_x = x * 60.0  # drive activations well past the 8.0 fallback range
    res = flow.run(targets=("jax",), dtconfig=DatatypeConfig(8, 32),
                   calib_inputs=(big_x,))
    # res.graph carries dtconfig annotations; strip them for the float ref
    from repro.core.passes import strip_precision
    float_ranges = flow.calibrate(big_x, graph=strip_precision(res.graph))
    for k, v in float_ranges.items():
        assert res.act_ranges[k] == pytest.approx(v), k


# ---------------------------------------------------------------------------
# constant folding / DCE
# ---------------------------------------------------------------------------

def test_constant_folding_precomputes_weight_subgraph():
    inits = {"w": np.full((4, 4), 2.0, np.float32),
             "wa": np.full((4, 4), 0.5, np.float32),
             "b": np.zeros((4,), np.float32)}
    g = Graph("t", [
        Node("Add", "prep", ["w", "wa"], ["w_sum"]),
        Node("Gemm", "fc", ["input", "w_sum", "b"], ["out"]),
    ], [TensorInfo("input", (1, 4))], ["out"], inits)
    folded = eliminate_dead_nodes(fold_constants(g))
    assert [n.op for n in folded.topo_order()] == ["Gemm"]
    np.testing.assert_allclose(folded.initializers["w_sum"],
                               np.full((4, 4), 2.5, np.float32))
    x = jnp.ones((1, 4))
    np.testing.assert_allclose(np.asarray(JaxWriter(folded).build()(x)),
                               np.asarray(JaxWriter(g).build()(x)))


def test_dce_removes_unreachable_nodes(mlp_graph):
    g, x = mlp_graph
    dead = Node("Relu", "dead_tap", ["fc0_out"], ["dead_out"])
    g2 = Graph(g.name, g.nodes + [dead], g.inputs, g.outputs,
               dict(g.initializers, unused=np.zeros((2, 2), np.float32)))
    cleaned = eliminate_dead_nodes(g2)
    names = [n.name for n in cleaned.nodes]
    assert "dead_tap" not in names
    assert "unused" not in cleaned.initializers
    assert len(names) == len(g.nodes)
    np.testing.assert_allclose(np.asarray(JaxWriter(cleaned).build()(x)),
                               np.asarray(JaxWriter(g).build()(x)))


# ---------------------------------------------------------------------------
# shape inference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("which", ["cnn", "mlp"])
def test_shape_inference_matches_executed_shapes(which, cnn_graph, mlp_graph):
    g, x = cnn_graph if which == "cnn" else mlp_graph
    for graph in (g, PassManager(default_pipeline(None)).run(g)):
        infer_shapes(graph)
        _, env = JaxWriter(graph).build(capture=True)(x)
        for n in graph.nodes:
            for o in n.outputs:
                assert tuple(graph.value_info[o].shape) == tuple(env[o].shape), \
                    f"{which}:{o}"


# ---------------------------------------------------------------------------
# multi-output ops (Split) — regression for the outputs[0]-only bug
# ---------------------------------------------------------------------------

def test_shape_inference_explicit_asymmetric_pads():
    """ONNX explicit pads [t, l, b, r] are applied per axis."""
    g = Graph("t", [
        Node("Conv", "c", ["input", "w"], ["out"],
             {"kernel_shape": [3, 3], "pads": [1, 0, 1, 0], "strides": [1, 1]}),
    ], [TensorInfo("input", (1, 8, 10, 1))], ["out"],
        {"w": np.zeros((3, 3, 1, 2), np.float32)})
    infer_shapes(g)
    # H: 8 + (1+1) - 3 + 1 = 8 ; W: 10 + 0 - 3 + 1 = 8
    assert tuple(g.value_info["out"].shape) == (1, 8, 8, 2)


def test_split_binds_every_output():
    g = Graph("t", [
        Node("Split", "sp", ["input"], ["a", "b"], {"axis": -1}),
        Node("Add", "sum", ["a", "b"], ["out"]),
    ], [TensorInfo("input", (2, 6))], ["out"])
    infer_shapes(g)
    assert tuple(g.value_info["a"].shape) == (2, 3)
    x = jnp.arange(12, dtype=jnp.float32).reshape(2, 6)
    out = JaxWriter(g).build()(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x[:, :3] + x[:, 3:]))


# ---------------------------------------------------------------------------
# precision assignment + exploration
# ---------------------------------------------------------------------------

def test_assign_precision_is_functional(mlp_graph):
    g, _ = mlp_graph
    pm = PrecisionMap(DatatypeConfig(16, 8), {"fc1": DatatypeConfig(16, 4)})
    g2 = make_assign_precision(pm)(g)
    assert all(n.dtconfig is None for n in g.nodes)        # original untouched
    assert {n.name: n.dtconfig for n in g2.nodes}["fc1"] == DatatypeConfig(16, 4)
    assert {n.name: n.dtconfig for n in g2.nodes}["fc0"] == DatatypeConfig(16, 8)


def test_explorer_returns_runnable_heterogeneous_map(mlp_graph):
    g, x = mlp_graph
    flow = DesignFlow(g)
    pm, history = flow.explore_mixed_precision((x,), ladder=(16, 8, 4),
                                               tol=0.5)
    assert isinstance(pm, PrecisionMap)
    assert set(pm.per_node) == {"fc0", "fc1"}
    assert history, "greedy search should accept at least one move"
    assert any(c.weight_bits < 16 for c in pm.per_node.values())
    res = flow.run(targets=("jax",), dtconfig=pm, calib_inputs=(x,))
    assert res.executables["jax"](x).shape == (2, 5)
