"""Optimizer, data pipeline and gradient-compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import DataConfig, TokenStream, batch_at
from repro.optim.adamw import (OptConfig, apply_updates, init_opt_state,
                               schedule)
from repro.quant import gradcomp


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.2, weight_decay=0.0, warmup_steps=1, total_steps=200,
                    clip_norm=100.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0, warmup_steps=0,
                    total_steps=10)
    _, _, metrics = apply_updates(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, 5)) < float(schedule(cfg, 10))
    assert abs(float(schedule(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, 100)) - 0.1) < 1e-6


def test_weight_decay_skips_norms():
    params = {"a/norm/w": jnp.ones(4), "a/w_up": jnp.ones((2, 2))}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.5, warmup_steps=0, total_steps=10)
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    p2, _, _ = apply_updates(params, zeros, state, cfg)
    np.testing.assert_array_equal(np.asarray(p2["a/norm/w"]), 1.0)
    assert float(p2["a/w_up"][0, 0]) < 1.0  # decayed


def test_token_stream_cursor_resume():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=3)
    s1 = TokenStream(cfg)
    batches = [next(s1) for _ in range(5)]
    s2 = TokenStream.restore(cfg, {"step": 3, "seed": 3})
    b3 = next(s2)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(batches[3]["tokens"]))


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=3)
    b = batch_at(cfg, 0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_gradcomp_error_feedback_unbiased():
    """With error feedback, the accumulated compressed sum tracks the true sum."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (256,))
    err = jnp.zeros((256,), jnp.bfloat16)
    acc = jnp.zeros((256,))
    for i in range(50):
        deq, err = gradcomp.compress_decompress(g_true, err)
        acc = acc + deq
    rel = float(jnp.linalg.norm(acc - 50 * g_true) / jnp.linalg.norm(50 * g_true))
    assert rel < 0.01, rel


def test_gradcomp_tree():
    grads = {"a": jnp.ones(8), "b": jnp.full((4,), -2.0)}
    err = gradcomp.init_error_state(grads)
    g2, e2 = gradcomp.compress_tree(grads, err)
    assert set(g2) == set(grads)
    np.testing.assert_allclose(np.asarray(g2["a"]), 1.0, atol=0.02)
