"""Roofline-parser and pruning unit tests."""
import jax.numpy as jnp
import numpy as np

from repro.launch.roofline import (CollectiveStats, RooflineReport,
                                   model_flops_for, parse_collectives)
from repro.quant.pruning import magnitude_prune, nm_prune, prune_tree

HLO_SAMPLE = """
ENTRY %main {
  %ar = f32[16,1024]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  %ag = bf16[4096,512]{1,0} all-gather(%p0), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %rs = bf16[256,512]{1,0} reduce-scatter(%x), channel_id=3, replica_groups=[2,8]<=[16], to_apply=%add
  %cp = u8[128]{0} collective-permute(%y), channel_id=4, source_target_pairs={{0,1}}
  %no = f32[2,2]{1,0} add(%a, %b)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO_SAMPLE)
    assert st.counts == {"all-reduce": 1, "all-gather": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    ar = 2 * 15 / 16 * 16 * 1024 * 4
    ag = 15 / 16 * 4096 * 512 * 2
    rs = 7 * 256 * 512 * 2
    cp = 128
    np.testing.assert_allclose(st.wire_bytes, ar + ag + rs + cp, rtol=1e-6)


def test_parse_tuple_shapes():
    txt = ('%t = (f32[8,8]{1,0}, f32[4]{0}) all-reduce(%a, %b), '
           'replica_groups=[4,64]<=[256], to_apply=%add')
    st = parse_collectives(txt)
    assert st.counts["all-reduce"] == 1
    np.testing.assert_allclose(st.raw_bytes, 8 * 8 * 4 + 4 * 4)


def test_roofline_bound_selection():
    coll = CollectiveStats(counts={}, bytes_by_op={}, wire_bytes=5e9,
                           raw_bytes=5e9)
    r = RooflineReport("a", "s", "16x16", 256, flops_per_device=1e12,
                       bytes_per_device=1e9, collective=coll, model_flops=1e15)
    assert r.collective_s > r.memory_s and r.collective_s > r.compute_s
    assert r.bound == "collective"
    assert 0 < r.mfu < 1


def test_model_flops_kinds():
    from repro.configs import get_config
    from repro.configs.base import TRAIN_4K, PREFILL_32K, DECODE_32K
    cfg = get_config("qwen1.5-0.5b")
    n = cfg.active_param_count()
    assert model_flops_for(cfg, TRAIN_4K, n) == 6 * n * 256 * 4096
    assert model_flops_for(cfg, PREFILL_32K, n) == 2 * n * 32 * 32768
    assert model_flops_for(cfg, DECODE_32K, n) == 2 * n * 128


def test_magnitude_prune_fraction():
    w = jnp.arange(1.0, 101.0)
    p = magnitude_prune(w, 0.25)
    assert float(jnp.mean((p == 0))) == 0.25
    # keeps the largest magnitudes
    assert float(p[-1]) == 100.0 and float(p[0]) == 0.0


def test_nm_prune_structure():
    w = jnp.array([[1.0, -5.0, 0.1, 3.0, 2.0, -0.2, 4.0, 0.3]])
    p = nm_prune(w, n=2, m=4)
    assert float(jnp.mean((p == 0))) == 0.5
    # each group of 4 keeps exactly its 2 largest |values|
    np.testing.assert_array_equal(np.asarray(p[0, :4] != 0), [False, True, False, True])


def test_prune_tree_skips_norms():
    tree = {"a/w_up": jnp.ones((8, 8)), "a/norm/w": jnp.ones(8)}
    out, stats = prune_tree(tree, 0.5)
    np.testing.assert_array_equal(np.asarray(out["a/norm/w"]), 1.0)
    assert 0.4 <= stats["zero_weight_frac"] <= 0.6
