"""End-to-end behaviour tests for the paper's system: the full ONNX->accelerator
flow on a *trained* classifier, validating the paper's Table II claim
*orderings* (C1-C3) on the procedural MNIST dataset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN
from repro.core.flow import DesignFlow
from repro.core.reader import cnn_to_ir
from repro.data.mnist import make_dataset
from repro.models import cnn
from repro.quant.qtypes import DatatypeConfig


@pytest.fixture(scope="module")
def trained_cnn():
    """Train the paper's CNN briefly on procedural MNIST (CPU, ~1 min)."""
    imgs, labels = make_dataset(1024, seed=0)
    test_x, test_y = make_dataset(256, seed=99)
    params = cnn.init_params(CNN, jax.random.PRNGKey(0))

    @jax.jit
    def step(params, x, y):
        (loss, aux), g = jax.value_and_grad(cnn.loss_fn, has_aux=True)(
            params, x, y, CNN)
        params = {k: v - 0.05 * g[k] for k, v in params.items()}
        # update running bn stats
        for k, v in aux.items():
            params[k] = 0.9 * params[k] + 0.1 * v
        return params, loss

    bs = 64
    for epoch in range(6):
        for i in range(0, 1024, bs):
            params, loss = step(params, jnp.asarray(imgs[i:i + bs]),
                                jnp.asarray(labels[i:i + bs]))
    acc = float(cnn.accuracy(params, jnp.asarray(test_x),
                             jnp.asarray(test_y), CNN))
    return params, acc, (test_x, test_y)


def test_cnn_learns_above_chance(trained_cnn):
    _, acc, _ = trained_cnn
    assert acc > 0.7, f"trained accuracy {acc}"


def _flow_accuracy(params, dt, test):
    test_x, test_y = test
    g = cnn_to_ir(CNN, {k: np.asarray(v) for k, v in params.items()},
                  batch=len(test_y))
    flow = DesignFlow(g)
    calib = (jnp.asarray(test_x[:64]),)
    res = flow.run(targets=("jax",), dtconfig=dt, calib_inputs=calib)
    logits = res.executables["jax"](jnp.asarray(test_x))
    acc = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(test_y))))
    return acc, res.stats


def test_paper_claim_c1_weight_precision_robust(trained_cnn):
    """C1: dropping W16->W8->W4 barely hurts accuracy (paper: 98/98/97)."""
    params, acc_f, test = trained_cnn
    accs = {wb: _flow_accuracy(params, DatatypeConfig(16, wb), test)[0]
            for wb in (16, 8, 4)}
    for wb, a in accs.items():
        assert a > acc_f - 0.1, f"W{wb}: {a} vs float {acc_f}"


def test_paper_claim_c2_activation_precision_fragile(trained_cnn):
    """C2: aggressive activation quantization hurts more than weight quant
    (paper: D8-W16 76% vs D16-W8 98%)."""
    params, acc_f, test = trained_cnn
    acc_w8, _ = _flow_accuracy(params, DatatypeConfig(16, 8), test)
    acc_d4, _ = _flow_accuracy(params, DatatypeConfig(4, 16), test)
    assert acc_w8 - acc_d4 > 0.05, (acc_w8, acc_d4)


def test_paper_claim_c3_zero_weights_grow(trained_cnn):
    """C3: zero-weight fraction rises steeply at W4/W2 (paper: 55%/86%)."""
    params, _, test = trained_cnn
    _, s4 = _flow_accuracy(params, DatatypeConfig(16, 4), test)
    _, s2 = _flow_accuracy(params, DatatypeConfig(16, 2), test)
    _, s16 = _flow_accuracy(params, DatatypeConfig(16, 16), test)
    assert s2["zero_weight_frac"] > s4["zero_weight_frac"] > \
        s16["zero_weight_frac"]
    assert s2["zero_weight_frac"] > 0.3
