"""Weight-memory integrity: CRC-sealed regions on the packed buffer, the
rate-bounded scrubber (detect / repair-in-place / quarantine), the fatal
escalation through AccelServer into fleet ejection with a ``quarantined``
cause, semantic canaries, the NaN/Inf output guard, seeded SEU injection,
and the hardened JSON deserializers (Pareto fronts, autotune cache).
"""

import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dse.pareto import FrontFormatError, ParetoFront, ParetoPoint
from repro.kernels import autotune
from repro.quant.pack import PACK_ALIGN, PackedWeights
from repro.runtime.fleet import FleetRouter, HealthState
from repro.runtime.integrity import (BitFlipInjector, CanarySet,
                                     IntegrityError, Scrubber)
from repro.runtime.serve import AccelServer, NumericalFault


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_packed(with_views=True):
    """Two small quantizable weights; optionally derive the W4/W2 views so
    every region kind (codes, scale, view) exists."""
    rng = np.random.default_rng(0)
    pw = PackedWeights.from_initializers({
        "fc/w": rng.standard_normal((16, 24)).astype(np.float32),
        "out/w": rng.standard_normal((24, 8)).astype(np.float32),
    })
    if with_views:
        for t in pw.tensors.values():
            t.packed_view(4)
            t.packed_view(2)
    return pw


def snapshot(pw):
    """Golden copies of every live buffer, for restore between flips."""
    return {(n, "codes"): np.array(t.codes) for n, t in pw.tensors.items()} \
        | {(n, "scale"): np.array(t.scale) for n, t in pw.tensors.items()} \
        | {(n, "view", b, a): np.array(buf)
           for n, t in pw.tensors.items()
           for (b, a), buf in t._packed.items()}


def restore(pw, golden):
    for n, t in pw.tensors.items():
        t.codes = jnp.asarray(golden[(n, "codes")])
        t.scale = jnp.asarray(golden[(n, "scale")])
        t.seal()
        for (b, a) in list(t._packed):
            t.repair_view(b, align=a)


# ---------------------------------------------------------------------------
# region checksums: detection sweep
# ---------------------------------------------------------------------------


def test_verify_catches_any_single_bit_flip_in_any_region():
    # seeded sweep: several random (byte, bit) flips per region, at every
    # region kind — verify() must name exactly the corrupted region
    pw = make_packed()
    golden = snapshot(pw)
    regions = pw.regions()
    assert {r.kind for r in regions} == {"codes", "scale", "view"}
    assert len(regions) == 2 * 4          # 2 tensors x (codes, scale, v4, v2)
    for i, region in enumerate(regions):
        for seed in range(3):
            inj = BitFlipInjector(pw, seed=100 * i + seed)
            rec = inj.flip(region=region)
            mismatches = pw.verify()
            assert [m.region for m in mismatches] == [region], \
                f"flip {rec} in {region.label()} not isolated"
            assert mismatches[0].repairable == (region.kind == "view")
            restore(pw, golden)
    assert pw.verify() == []


def test_verify_bits_filter_sees_the_serving_points_regions():
    # per-working-point verification: the bits filter must cover exactly
    # the buffers that point serves from
    pw = make_packed()
    golden = snapshot(pw)
    inj = BitFlipInjector(pw, seed=7)
    # W2 view flip: invisible to the W8 path, caught by W2 and the full scan
    v2 = next(r for r in pw.regions() if r.kind == "view" and r.bits == 2)
    inj.flip(region=v2)
    assert pw.verify(bits=8) == []
    assert [m.region for m in pw.verify(bits=2)] == [v2]
    restore(pw, golden)
    # master-code flip: the W8 path and the full scan see it
    codes = next(r for r in pw.regions() if r.kind == "codes")
    inj.flip(region=codes)
    assert [m.region for m in pw.verify(bits=8)] == [codes]
    assert codes in [m.region for m in pw.verify()]
    restore(pw, golden)


def test_view_repair_is_bit_exact_from_master():
    pw = make_packed()
    golden = snapshot(pw)
    v4 = next(r for r in pw.regions() if r.kind == "view" and r.bits == 4)
    BitFlipInjector(pw, seed=3).flip(region=v4)
    [m] = pw.verify()
    pw.repair(m)
    assert pw.verify() == []
    buf = np.array(pw.tensors[v4.tensor]._packed[(v4.bits, v4.align)])
    assert np.array_equal(buf, golden[(v4.tensor, "view", v4.bits, v4.align)])


def test_repair_refuses_unrepairable_regions():
    pw = make_packed()
    codes = next(r for r in pw.regions() if r.kind == "codes")
    BitFlipInjector(pw, seed=4).flip(region=codes)
    [m] = pw.verify(bits=8)
    assert not m.repairable and "UNREPAIRABLE" in str(m)
    with pytest.raises(ValueError, match="cannot repair"):
        pw.repair(m)


def test_packed_view_cache_is_thread_safe():
    # hammer first-touch derivation: every thread must get the identical
    # sealed buffer, with exactly one cache entry and one checksum per view
    pw = make_packed(with_views=False)
    t = pw.tensors["fc/w"]
    results, errs = [], []
    start = threading.Barrier(8)

    def worker(bits):
        try:
            start.wait(5.0)
            for _ in range(50):
                results.append((bits, np.array(t.packed_view(bits))))
        except Exception as e:                      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(b,))
               for b in (4, 2) for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(10.0)
    assert not errs
    assert set(t._packed) == {(4, PACK_ALIGN), (2, PACK_ALIGN)}
    for bits in (4, 2):
        bufs = [b for bb, b in results if bb == bits]
        assert all(np.array_equal(bufs[0], b) for b in bufs)
    assert pw.verify() == []        # checksums sealed consistently


# ---------------------------------------------------------------------------
# scrubber
# ---------------------------------------------------------------------------


def test_scrubber_detects_and_repairs_view_flip():
    pw = make_packed()
    golden = snapshot(pw)
    repaired = []
    sc = Scrubber(pw, on_repair=repaired.append)
    v2 = next(r for r in pw.regions() if r.kind == "view" and r.bits == 2)
    BitFlipInjector(pw, seed=5).flip(region=v2)
    sc.scrub_once()                 # one full pass catches any single flip
    assert sc.detected_flips == 1 and sc.repaired_views == 1
    assert sc.quarantines == 0 and sc.fatal is None
    assert [m.region for m in repaired] == [v2]
    assert pw.verify() == []
    buf = np.array(pw.tensors[v2.tensor]._packed[(v2.bits, v2.align)])
    assert np.array_equal(buf, golden[(v2.tensor, "view", v2.bits, v2.align)])


def test_scrubber_quarantines_master_corruption_once():
    pw = make_packed()
    quarantined = []
    sc = Scrubber(pw, on_quarantine=quarantined.append)
    codes = next(r for r in pw.regions() if r.kind == "codes")
    BitFlipInjector(pw, seed=6).flip(region=codes)
    for _ in range(3):              # repeated passes must not re-escalate
        sc.scrub_once()
    assert sc.quarantines == 1 and len(quarantined) == 1
    assert sc.detected_flips == 1   # quarantined region is off-duty
    assert sorted(sc.quarantined) == [codes.label()]
    err = sc.fatal
    assert isinstance(err, IntegrityError)
    assert [m.region for m in err.mismatches] == [codes]
    assert sc.telemetry()["quarantines"] == 1


def test_scrubber_never_repairs_view_from_corrupt_master():
    # a view flip whose master is ALSO corrupt must not be re-derived (that
    # would launder the corruption); both regions end up quarantined
    pw = make_packed()
    t = pw.tensors["fc/w"]
    regs = {r.kind if r.kind != "view" else (r.kind, r.bits): r
            for r in t.regions("fc/w")}
    inj = BitFlipInjector(pw, seed=8)
    inj.flip(region=regs["codes"])
    inj.flip(region=regs[("view", 4)])
    sc = Scrubber(pw)
    sc.scrub_once()
    assert sc.repaired_views == 0
    assert set(sc.quarantined) == {regs["codes"].label(),
                                   regs[("view", 4)].label()}


def test_scrubber_rate_bound_and_round_robin():
    pw = make_packed()
    clock = FakeClock()
    n = len(pw.regions())
    per_pass = sum(r.nbytes for r in pw.regions())
    biggest = max(r.nbytes for r in pw.regions())
    # rate = one full pass per second; a 0.25s tick funds ~a quarter pass
    sc = Scrubber(pw, rate_bytes_s=per_pass, interval_s=0.01, clock=clock)
    assert sc.period_bytes() == per_pass
    sc._tick()                      # first tick only arms the clock
    clock.advance(0.25)
    sc._tick()
    assert 0 < sc.scrubbed_bytes <= 0.25 * per_pass + biggest
    assert 0 < sc._cursor < n       # partial pass: cursor mid-list
    # four more funded ticks complete at least one full round-robin pass
    for _ in range(4):
        clock.advance(0.3)
        sc._tick()
    assert sc.scrub_passes >= 1


def test_scrubber_budget_cap_bounds_a_stall_burst():
    pw = make_packed()
    clock = FakeClock()
    per_pass = sum(r.nbytes for r in pw.regions())
    sc = Scrubber(pw, rate_bytes_s=per_pass, interval_s=0.01, clock=clock)
    sc._tick()
    clock.advance(1000.0)           # a long stall accrues a huge allowance
    sc._tick()                      # ...but bursts at most ~2 full passes
    assert sc.scrubbed_bytes <= 2 * per_pass
    assert sc.scrub_passes <= 2


def test_scrubber_daemon_lifecycle():
    pw = make_packed()
    sc = Scrubber(pw, rate_bytes_s=50e6, interval_s=0.001)
    with sc:
        assert sc.alive
        with pytest.raises(RuntimeError, match="already running"):
            sc.start()
        deadline = time.monotonic() + 5.0
        while sc.scrub_passes < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert sc.scrub_passes >= 2
    assert not sc.alive
    p = sc.scrub_passes
    time.sleep(0.02)
    assert sc.scrub_passes == p     # really stopped


def test_scrubber_rejects_bad_config():
    pw = make_packed()
    with pytest.raises(ValueError):
        Scrubber(pw, rate_bytes_s=0)
    with pytest.raises(ValueError):
        Scrubber(pw, interval_s=-1)


# ---------------------------------------------------------------------------
# escalation: scrubber -> AccelServer -> fleet
# ---------------------------------------------------------------------------


def shared_exe(pw):
    """A tiny 'working point' reading the LIVE master codes (not a traced
    constant), so served results actually depend on the shared buffer."""
    def exe(x):
        w = np.array(pw.tensors["fc/w"].codes, np.float32)
        return np.asarray(x, np.float32) @ w
    return exe


def test_attach_scrubber_kills_server_on_quarantine():
    pw = make_packed()
    srv = AccelServer(shared_exe(pw), max_batch=4, max_wait=0.001)
    sc = Scrubber(pw)
    srv.attach_scrubber(sc)
    assert srv.scrubber is sc
    with srv:
        assert float(np.asarray(srv(np.ones((1, 16), np.float32))).sum()) \
            == pytest.approx(float(np.array(pw.tensors["fc/w"].codes).sum()))
        codes = next(r for r in pw.regions() if r.kind == "codes")
        BitFlipInjector(pw, seed=9).flip(region=codes)
        sc.scrub_once()             # detection -> quarantine -> fatal pump
        assert isinstance(srv.fatal, IntegrityError)
        deadline = time.monotonic() + 5.0
        while srv.alive and time.monotonic() < deadline:
            time.sleep(0.005)
        assert not srv.alive        # refuses further work: no corrupted
        with pytest.raises(RuntimeError):   # result is served post-detection
            srv.submit(np.ones((1, 16), np.float32))
        assert srv.stats()["integrity"]["quarantines"] == 1


def test_fleet_ejects_quarantined_replica_and_heals_via_factory():
    pw = make_packed()
    golden = snapshot(pw)
    scrubbers = []

    def factory():
        if pw.verify():             # heal path: restore the pristine master
            restore(pw, golden)
        srv = AccelServer(shared_exe(pw), max_batch=4, max_wait=0.001)
        sc = Scrubber(pw, rate_bytes_s=50e6, interval_s=0.001)
        srv.attach_scrubber(sc)
        sc.start()
        scrubbers.append(sc)
        return srv

    r = FleetRouter({"a": factory}, probe=[np.ones((1, 16), np.float32)],
                    probe_interval_s=0.01, heal_cooldown_s=0.05,
                    default_deadline_s=15.0)
    try:
        with r:
            assert r(np.ones((1, 16), np.float32)) is not None
            codes = next(rg for rg in pw.regions() if rg.kind == "codes")
            BitFlipInjector(pw, seed=10).flip(region=codes)
            rep = r.replicas["a"]
            deadline = time.monotonic() + 10.0
            while rep.eject_cause != "quarantined" \
                    and time.monotonic() < deadline:
                time.sleep(0.01)
            assert rep.eject_cause == "quarantined"
            # the dead generation's scrubber still backs the fleet telemetry
            # until the heal swaps it out
            assert r.stats()["integrity"]["quarantines"] >= 1
            # heal: factory restores the master and the sentinel readmits
            deadline = time.monotonic() + 10.0
            while not (rep.state == HealthState.HEALTHY
                       and rep.server.alive) \
                    and time.monotonic() < deadline:
                time.sleep(0.01)
            s = r.stats()
            assert s["replicas"]["a"]["eject_cause"] == "quarantined"
            assert s["replicas"]["a"]["readmissions"] >= 1
            # the healed fleet's LIVE scrubber starts clean
            assert s["integrity"]["quarantined"] == []
            assert r(np.ones((1, 16), np.float32)) is not None
    finally:
        for sc in scrubbers:
            sc.stop()


def test_fleet_canary_failure_names_the_eject():
    # semantic corruption: the replica stays alive and finite but answers
    # outside every captured fingerprint -> probe returns "canary"
    drift = {"on": False}

    def exe(x):
        out = np.asarray(x, np.float32) * 2.0
        return out + 1.0 if drift["on"] else out

    cs = CanarySet.capture({"p": lambda x: np.asarray(x, np.float32) * 2.0},
                           [(np.ones((1, 3), np.float32),)], k=1)
    r = FleetRouter({"a": lambda: AccelServer(exe, max_batch=4,
                                              max_wait=0.001)},
                    canaries=cs, probe_interval_s=0.01,
                    heal_cooldown_s=0.05, default_deadline_s=15.0)
    with r:
        rep = r.replicas["a"]
        assert r._probe(rep) is None
        drift["on"] = True
        assert r._probe(rep) == "canary"
        assert r.stats()["canary_failures"] >= 1
        with r._lock:
            rep.state = HealthState.SUSPECT    # make the sentinel probe it
        deadline = time.monotonic() + 10.0
        while rep.eject_cause != "canary" and time.monotonic() < deadline:
            time.sleep(0.01)
        assert rep.eject_cause == "canary"


# ---------------------------------------------------------------------------
# NaN/Inf output guard
# ---------------------------------------------------------------------------


def poison_marked(x):
    """NaN-poison exactly the rows whose marker column is 13 — batch
    neighbours stay clean, so the guard's per-request demux is observable."""
    out = np.asarray(x, np.float32) * 2.0
    out[np.asarray(x)[:, 0] == 13.0] = np.nan
    return out


def test_nan_guard_withholds_only_the_poisoned_request():
    srv = AccelServer(poison_marked, max_batch=8, max_wait=0.05)
    with srv:
        bad = srv.submit(np.full((1, 3), 13.0, np.float32))
        good = srv.submit(np.full((1, 3), 2.0, np.float32))
        assert float(srv.result(good, timeout=10)[0, 0]) == 4.0
        with pytest.raises(NumericalFault):
            srv.result(bad, timeout=10)
        s = srv.stats()
        assert s["numerical_faults"] == 1
        assert s["submitted"] == 2  # the clean neighbour was not withheld


def test_nan_guard_catches_inf_and_spares_integer_outputs():
    def exe(x):
        xs = np.asarray(x, np.float32)
        return np.where(xs[:, :1] == 13.0, np.inf, 1.0).astype(np.float32), \
            np.ones((xs.shape[0], 2), np.int32)

    srv = AccelServer(exe, max_batch=4, max_wait=0.001)
    with srv:
        with pytest.raises(NumericalFault):
            srv(np.full((1, 3), 13.0, np.float32))
        f, i = srv(np.zeros((1, 3), np.float32))
        assert float(f[0, 0]) == 1.0 and i.dtype == np.int32


# ---------------------------------------------------------------------------
# canaries + injector
# ---------------------------------------------------------------------------


def test_canary_check_accepts_any_point_fingerprint():
    pts = {"w8": lambda x: np.asarray(x) * 2.0,
           "w2": lambda x: np.asarray(x) * 2.0 + 0.5}
    cs = CanarySet.capture(pts, [(np.ones((1, 4), np.float32),),
                                 (np.full((1, 4), 3.0, np.float32),)], k=2)
    assert len(cs) == 2
    x0 = cs.inputs(0)[0]
    assert cs.check(0, x0 * 2.0)            # the W8 fingerprint
    assert cs.check(0, x0 * 2.0 + 0.5)      # a brownout downshift to W2
    assert not cs.check(0, x0 * 2.0 + 0.3)  # neither point: corruption
    assert not cs.check(0, np.full_like(x0, np.nan))   # non-finite fails
    assert cs.inputs(2)[0] is cs.inputs(0)[0]          # mod indexing
    with pytest.raises(ValueError):
        CanarySet.capture(pts, [], k=2)


def test_bit_flip_injector_is_seed_deterministic():
    recs = []
    for _ in range(2):
        pw = make_packed()
        inj = BitFlipInjector(pw, seed=42)
        recs.append([(r.region.label(), r.byte, r.bit)
                     for r in (inj.flip(i) for i in range(6))])
    assert recs[0] == recs[1]
    assert len({r[0] for r in recs[0]}) > 1     # spreads across regions


def test_bit_flip_injector_schedule_fires_once_and_validates():
    pw = make_packed()
    inj = BitFlipInjector(pw, flip_at=[3], seed=1, kinds=("view",))
    assert inj.maybe_flip(2) is None
    rec = inj.maybe_flip(3)
    assert rec is not None and rec.region.kind == "view"
    assert inj.maybe_flip(3) is None            # fire-once
    assert inj.injected_flips == 1
    with pytest.raises(ValueError):
        BitFlipInjector(pw, rate=1.5)
    with pytest.raises(ValueError):
        BitFlipInjector(pw, kinds=("codes", "bogus"))


# ---------------------------------------------------------------------------
# hardened deserialization
# ---------------------------------------------------------------------------


def good_point_dict():
    return {"name": "w8", "weight_bits": 8, "act_dtype": "bfloat16",
            "act_bits": None, "weight_bytes": 1000, "fifo_bytes": 64,
            "scratch_bytes": 32, "predicted_latency_s": 1e-3,
            "measured_latency_s": None, "agreement": 0.99}


@pytest.mark.parametrize("corrupt", [
    {"name": ""},                          # empty name
    {"name": 7},                           # wrong-typed name
    {"weight_bits": 0},                    # below minimum
    {"weight_bits": 4.5},                  # fractional
    {"weight_bytes": -1},                  # negative bytes
    {"weight_bytes": float("nan")},        # non-finite int field
    {"fifo_bytes": True},                  # bool is not an int here
    {"predicted_latency_s": float("inf")},  # non-finite float
    {"predicted_latency_s": None},         # required float missing
    {"agreement": "high"},                 # wrong-typed float
    {"measured_latency_s": -0.5},          # negative optional float
])
def test_pareto_point_rejects_corrupted_fields(corrupt):
    d = good_point_dict() | corrupt
    with pytest.raises(FrontFormatError):
        ParetoPoint.from_dict(d)


def test_pareto_front_round_trips_and_rejects_garbage():
    p = ParetoPoint.from_dict(good_point_dict())
    front = ParetoFront("g", [p])
    again = ParetoFront.from_json(front.to_json())
    assert len(again) == 1
    assert again.points[0].to_dict() == p.to_dict()
    with pytest.raises(FrontFormatError, match="'points' must be a list"):
        ParetoFront.from_dict(front.to_dict() | {"points": {"w8": {}}})
    with pytest.raises(FrontFormatError, match="must be a dict"):
        ParetoFront.from_dict(front.to_dict() | {"points": ["w8"]})


def test_autotune_cache_drops_corrupt_entries_keeps_rest(tmp_path,
                                                         monkeypatch):
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({
        "schema": autotune.CACHE_SCHEMA,
        "entries": {"good": [64, 64, 128], "zero": [0, 64],
                    "negative": [-8], "boolean": [True, 64],
                    "fractional": [64.5], "stringy": "64",
                    "empty": []}}))
    monkeypatch.setenv(autotune.AUTOTUNE_CACHE_ENV, str(path))
    assert autotune.disk_cache() == {"good": (64, 64, 128)}
    # entries wrong-typed wholesale: the whole file is treated as empty
    path.write_text(json.dumps({"schema": autotune.CACHE_SCHEMA,
                                "entries": [["good", [64]]]}))
    monkeypatch.setenv(autotune.AUTOTUNE_CACHE_ENV, str(tmp_path / "x.json"))
    path.rename(tmp_path / "x.json")
    assert autotune.disk_cache() == {}


@pytest.mark.parametrize("blocks", [
    (0, 64), (-8,), (True, 64), (64.5,), (), "64", None])
def test_autotune_disk_put_is_strict(tmp_path, monkeypatch, blocks):
    monkeypatch.setenv(autotune.AUTOTUNE_CACHE_ENV,
                       str(tmp_path / "autotune.json"))
    with pytest.raises(autotune.CacheFormatError):
        autotune.disk_put("k", blocks)
