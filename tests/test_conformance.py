"""Differential conformance suite.

Property-based: random Conv/Gemm/Pool graphs are generated from a seed and
the *full* pass pipeline (``DesignFlow.run()``) is checked against the raw
node-by-node interpretation (``run(passes=())``) across batch sizes
{1, 3, 8} — all served from ONE batch-polymorphic artifact (symbolic batch
dim).  When ``hypothesis`` is installed the seeds are drawn by hypothesis;
otherwise a pinned seed sweep runs the same property, so the suite is active
even in minimal environments.
"""
import jax
import numpy as np
import pytest

from repro.core.flow import DesignFlow
from repro.core.ir import BATCH, Graph, Node, TensorInfo, concretize
from repro.quant.qtypes import DatatypeConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_EXAMPLES = 10
BATCHES = (1, 3, 8)


def seeded_property(fn):
    """Run ``fn(seed)`` under hypothesis when available, else over a pinned
    seed sweep (same property, deterministic examples)."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=N_EXAMPLES, deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", [1000003 * i + 17
                                            for i in range(N_EXAMPLES)])(fn)


# ---------------------------------------------------------------------------
# random graph generator (Conv / Gemm / Pool per the issue)
# ---------------------------------------------------------------------------

def random_graph(seed):
    """A random supported topology with a symbolic batch dim.

    CNN flavour: 1-2 blocks of Conv(SAME, stride 1)[+BN][+Relu][+MaxPool2x2]
    then Flatten+Gemm.  MLP flavour: Gemm/Relu stack.  Returns the graph;
    weights are baked in as initializers.
    """
    rng = np.random.default_rng(seed)
    nodes, inits = [], {}
    f32 = np.float32
    if rng.random() < 0.6:                                   # CNN flavour
        h = int(rng.choice([6, 8, 12]))
        cin = int(rng.choice([1, 2]))
        x = "input"
        in_shape = (BATCH, h, h, cin)
        for i in range(int(rng.integers(1, 3))):
            cout = int(rng.choice([2, 3, 4]))
            k = int(rng.choice([1, 3]))
            wn, bn = f"conv{i}/w", f"conv{i}/b"
            inits[wn] = (0.5 * rng.normal(size=(k, k, cin, cout))).astype(f32)
            inits[bn] = (0.2 * rng.normal(size=(cout,))).astype(f32)
            nodes.append(Node("Conv", f"conv{i}", [x, wn, bn],
                              [f"conv{i}_out"],
                              {"kernel_shape": [k, k], "pads": "SAME",
                               "strides": [1, 1]}))
            x = f"conv{i}_out"
            if rng.random() < 0.5:
                for stat, v in (("scale", rng.uniform(0.5, 1.5, cout)),
                                ("bias", 0.2 * rng.normal(size=cout)),
                                ("mean", 0.2 * rng.normal(size=cout)),
                                ("var", rng.uniform(0.5, 2.0, cout))):
                    inits[f"bn{i}/{stat}"] = v.astype(f32)
                nodes.append(Node("BatchNormalization", f"bn{i}",
                                  [x] + [f"bn{i}/{s}" for s in
                                         ("scale", "bias", "mean", "var")],
                                  [f"bn{i}_out"], {"epsilon": 1e-5}))
                x = f"bn{i}_out"
            if rng.random() < 0.5:
                nodes.append(Node("Relu", f"relu{i}", [x], [f"relu{i}_out"]))
                x = f"relu{i}_out"
            if h % 2 == 0 and rng.random() < 0.7:
                nodes.append(Node("MaxPool", f"pool{i}", [x],
                                  [f"pool{i}_out"],
                                  {"kernel_shape": [2, 2], "strides": [2, 2]}))
                x = f"pool{i}_out"
                h //= 2
            cin = cout
        nodes.append(Node("Flatten", "flatten", [x], ["flat"]))
        feat = h * h * cin
        x = "flat"
    else:                                                    # MLP flavour
        feat = int(rng.choice([6, 10, 16]))
        in_shape = (BATCH, feat)
        x = "input"
        for i in range(int(rng.integers(1, 3))):
            hidden = int(rng.choice([4, 8, 12]))
            wn, bn = f"hid{i}/w", f"hid{i}/b"
            inits[wn] = (0.5 * rng.normal(size=(feat, hidden))).astype(f32)
            inits[bn] = (0.2 * rng.normal(size=(hidden,))).astype(f32)
            nodes.append(Node("Gemm", f"hid{i}", [x, wn, bn],
                              [f"hid{i}_out"]))
            nodes.append(Node("Relu", f"hrelu{i}", [f"hid{i}_out"],
                              [f"hrelu{i}_out"]))
            x, feat = f"hrelu{i}_out", hidden
    classes = int(rng.choice([3, 5]))
    inits["out/w"] = (0.5 * rng.normal(size=(feat, classes))).astype(f32)
    inits["out/b"] = (0.2 * rng.normal(size=(classes,))).astype(f32)
    nodes.append(Node("Gemm", "out", [x, "out/w", "out/b"], ["logits"]))
    g = Graph(f"rand{seed}", nodes, [TensorInfo("input", in_shape)],
              ["logits"], inits)
    g.validate()
    return g


def _inputs_for(graph, seed):
    shape = concretize(graph.inputs[0].shape, max(BATCHES))
    return np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed % (2**31)), shape))


# ---------------------------------------------------------------------------
# differential properties
# ---------------------------------------------------------------------------

@seeded_property
def test_pipeline_matches_raw_interpretation(seed):
    """Full pass pipeline == raw interpretation (float), batch 1/3/8 from one
    batch-polymorphic artifact, with value_info agreeing at every batch."""
    g = random_graph(seed)
    flow = DesignFlow(g)
    x = _inputs_for(g, seed)
    raw = flow.run(passes=())
    full = flow.run()
    for b in BATCHES:
        y_raw = np.asarray(raw.batched["jax"](x[:b]))
        y_full = np.asarray(full.batched["jax"](x[:b]))
        scale = max(1.0, float(np.max(np.abs(y_raw))))
        np.testing.assert_allclose(y_full, y_raw, atol=1e-4 * scale,
                                   err_msg=f"seed={seed} batch={b}")
        info = full.graph.value_info["logits"]
        assert info.shape[0] == BATCH
        assert concretize(info.shape, b) == y_full.shape
    # one artifact, three traced batches — the graph was never recompiled
    assert full.batched["jax"].cached_batches == BATCHES
    assert full.batched["jax"].misses == len(BATCHES)


@seeded_property
def test_quantized_pipeline_within_quant_tolerance(seed):
    """D16-W16 compiled pipeline stays within quantization tolerance of the
    raw float interpretation at every batch size."""
    g = random_graph(seed)
    flow = DesignFlow(g)
    x = _inputs_for(g, seed)
    raw = flow.run(passes=())
    q = flow.run(dtconfig=DatatypeConfig(16, 16), calib_inputs=(x,))
    for b in BATCHES:
        y_raw = np.asarray(raw.batched["jax"](x[:b]))
        y_q = np.asarray(q.batched["jax"](x[:b]))
        scale = max(1.0, float(np.max(np.abs(y_raw))))
        assert float(np.max(np.abs(y_q - y_raw))) <= 1e-2 * scale, \
            f"seed={seed} batch={b}"


@seeded_property
def test_stream_target_matches_jax_target(seed):
    """The Pallas streaming target agrees with the reference target on the
    same compiled graph (float) for every generated topology and batch."""
    g = random_graph(seed)
    res = DesignFlow(g).run(targets=("jax", "stream"))
    x = _inputs_for(g, seed)
    for b in BATCHES:
        np.testing.assert_allclose(
            np.asarray(res.batched["stream"](x[:b])),
            np.asarray(res.batched["jax"](x[:b])),
            atol=1e-4, err_msg=f"seed={seed} batch={b}")


def test_batched_executable_lru_evicts_oldest_trace():
    g = random_graph(3)
    res = DesignFlow(g).run(batch_cache=2)
    exe = res.batched["jax"]
    x = _inputs_for(g, 3)
    for b in (1, 3, 8):
        exe(x[:b])
    assert exe.cached_batches == (3, 8)       # batch 1 evicted (LRU)
    assert (exe.hits, exe.misses) == (0, 3)
    exe(x[:8])                                 # hit: no retrace
    assert (exe.hits, exe.misses) == (1, 3)
    exe(x[:1])                                 # re-traced after eviction
    assert exe.misses == 4 and exe.cached_batches == (8, 1)


def test_symbolic_batch_survives_serialization(tmp_path):
    g = random_graph(11)
    path = str(tmp_path / "g.onnx.json")
    g.save(path)
    g2 = Graph.load(path)
    assert g2.inputs[0].shape == g.inputs[0].shape
    assert g2.inputs[0].is_batched
    res = DesignFlow(g2).run()
    y = res.batched["jax"](_inputs_for(g2, 11)[:3])
    assert y.shape == concretize(res.graph.value_info["logits"].shape, 3)


def test_reshape_without_wildcard_rejected_on_symbolic_batch():
    """A fully-concrete Reshape target cannot carry the symbolic batch —
    shape inference must refuse rather than record stale annotations."""
    from repro.core.passes.shape_infer import infer_shapes
    g = Graph("bad",
              [Node("Reshape", "r", ["input"], ["out"], {"shape": [3, 4]})],
              [TensorInfo("input", (BATCH, 2, 2))], ["out"])
    with pytest.raises(ValueError, match="wildcard"):
        infer_shapes(g)
