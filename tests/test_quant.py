import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.fixedpoint import dequantize, fake_quant, quantize, zero_fraction
from repro.quant.pack import pack_int2, pack_int4, unpack_int2, unpack_int4
from repro.quant.ptq import (derive_view, dequantize_tree,
                             quantize_tree_fixed, quantize_tree_native,
                             quant_memory_bytes)
from repro.quant.qtypes import (QType, TABLE2_POINTS,
                                fixed_for_range)


def test_qtype_basics():
    qt = QType(8, 4)
    assert qt.scale == 2 ** -4
    assert qt.qmin == -128 and qt.qmax == 127
    assert str(qt) == "Q4.4"


def test_fixed_for_range_covers():
    qt = fixed_for_range(16, 3.7)
    x = jnp.array([3.7, -3.7, 0.0])
    deq = dequantize(quantize(x, qt), qt)
    assert float(jnp.max(jnp.abs(deq - x))) < 2 * qt.scale


def test_quantize_saturates():
    qt = QType(4, 0)  # [-8, 7]
    assert float(quantize(jnp.array(100.0), qt)) == 7
    assert float(quantize(jnp.array(-100.0), qt)) == -8


def test_fake_quant_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    qt = QType(8, 5)
    y = fake_quant(x, qt)
    np.testing.assert_array_equal(np.asarray(fake_quant(y, qt)), np.asarray(y))


def test_fake_quant_straight_through_grad():
    qt = QType(8, 4)
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, qt)))(jnp.ones(4))
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_zero_fraction_increases_with_lower_bits():
    w = jax.random.normal(jax.random.PRNGKey(1), (1000,)) * 0.1
    fracs = []
    for bits in (16, 8, 4, 2):
        qt = fixed_for_range(bits, float(jnp.max(jnp.abs(w))))
        fracs.append(float(zero_fraction(w, qt)))
    assert fracs == sorted(fracs), f"zero fraction must rise as bits drop: {fracs}"


def test_pack_int4_roundtrip():
    codes = jnp.arange(-8, 8, dtype=jnp.int8).reshape(2, 8)
    packed = pack_int4(codes)
    assert packed.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)),
                                  np.asarray(codes))


def test_pack_int2_roundtrip():
    codes = jnp.array([[-2, -1, 0, 1] * 2], dtype=jnp.int8)
    packed = pack_int2(codes)
    assert packed.shape == (1, 2)
    np.testing.assert_array_equal(np.asarray(unpack_int2(packed)),
                                  np.asarray(codes))


def test_derive_view_nested():
    """W4/W2 views of the int8 master stay on coarser grids of the same scale."""
    codes = jnp.arange(-127, 128, dtype=jnp.int8)
    v4 = derive_view(codes, 4)
    v2 = derive_view(codes, 2)
    assert set(np.asarray(v4).tolist()) <= set(range(-128, 128, 16))
    assert set(np.asarray(v2).tolist()) <= set(range(-128, 128, 64))
    # w8 view is the identity
    np.testing.assert_array_equal(np.asarray(derive_view(codes, 8)),
                                  np.asarray(codes))


def test_native_quant_error_bounds():
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32))
    params = {"layer/w_up": w}
    qp = quantize_tree_native(params)
    for bits, tol in ((8, 2 / 127), (4, 2 / 7), (2, 2.1)):
        deq = dequantize_tree(qp, bits, jnp.float32)["layer/w_up"]
        err = float(jnp.max(jnp.abs(deq - w)))
        scale = float(jnp.max(jnp.abs(w)))
        assert err <= tol * scale, (bits, err, tol * scale)


def test_quantize_tree_fixed_table2_points():
    params = {"a/w_up": jax.random.normal(jax.random.PRNGKey(3), (32, 16)),
              "a/norm/w": jnp.ones(16)}
    for dt in TABLE2_POINTS:
        q, stats = quantize_tree_fixed(params, dt)
        assert q["a/norm/w"].shape == (16,)          # norms untouched
        assert 0.0 <= stats["zero_weight_frac"] <= 1.0
        if dt.weight_bits >= 32:
            np.testing.assert_array_equal(np.asarray(q["a/w_up"]),
                                          np.asarray(params["a/w_up"]))


def test_quant_memory_bytes_packed_scaling():
    params = {"l/w_up": jnp.ones((128, 128), jnp.float32)}
    qp = quantize_tree_native(params)
    b8 = quant_memory_bytes(qp, 8)
    b4 = quant_memory_bytes(qp, 4)
    b2 = quant_memory_bytes(qp, 2)
    n = 128 * 128
    assert b8 - b4 == n // 2 and b4 - b2 == n // 4
